"""Migration layer 3: the dual-version serving window.

While a plan drains, the system is BETWEEN versions: some data already
sits at its v+1 owner, the rest still at its v owner.  ``LiveMigration``
owns that window and gives readers one total rule (DESIGN.md section 8):

    route(id) = v   owner  if id's move is still pending,
                v+1 owner  otherwise (landed, or never had to move)

Equivalently: route to the v+1 owner iff the id's move has landed.  The
"pending" formulation is what makes ROLLBACK free: reversing a
half-landed migration is just a new LiveMigration whose plan is the
landed rows with src/dst swapped and v_from/v_to swapped -- unlanded
rows of the original never moved, so under the reversed rule they fall
into the "not in plan -> v_to(reverse) = v(original) owner" case, which
is exactly where they physically are.

``route_replicas[_device]`` is the per-slot REPLICA generalization
(DESIGN.md section 10): each slot of an id's R-replica set is
independently v or v+1 by its own landed bit --

    route_replicas(id)[r] = plan.src of (id, r)  while that slot's copy
                            is pending (the vacated v-side node still
                            holding the bytes),
                            v+1 set's slot r     otherwise

-- so every served set is R pairwise-distinct nodes that all physically
hold the datum at every round.  Rollback stays free: the reverse plan
swaps src/dst AND slot/src_slot, re-indexing slots into the reverse
destination (= original v) set.

Both versions' placements come from the engine's artifact LRU (no table
re-upload during the window, no matter how often the router flaps) and
the device paths keep the whole rule on device: the fused dual-table
diff kernels supply the owners, sorted-membership probes against the
(per-slot) pending sets supply the landed bits, and one ``where`` merges
them -- zero host syncs after the per-round control-path update.
"""

from __future__ import annotations

import functools

import numpy as np

from .drain import DrainDriver
from .mover import MigrationState, ThrottledMover


@functools.cache
def _member_fn():
    """Jitted sorted-set membership (lazy: no jax import on the host path)."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def member(ids, sorted_pad, n):
        pos = jnp.searchsorted(sorted_pad, ids.astype(jnp.uint32), side="left")
        pos_c = jnp.minimum(pos, sorted_pad.shape[0] - 1)
        return (pos < n) & (sorted_pad[pos_c] == ids)

    return member


_ROUTE_CACHE: dict = {}


def probe_trace_count(kind: str = "replica_route") -> int:
    """Total jit traces of the fused window probes so far (the tests'
    tripwire that repeated serving batches stop retracing).  The count
    lives on the process-wide ``obs`` ledger now (the probe cache is
    module-level, so its counter is too); this alias keeps the PR-7
    call sites reading the same way."""
    from repro.obs import get_ledger

    return get_ledger().counter(f"migrate.live.{kind}_traces")


def _fused_replica_route(statics: tuple):
    """ONE jit for the whole replica read rule, cached per
    ``(top_level, s_log2, max_draws, n_replicas)``.

    The batched serving driver calls ``route_replicas_device`` every
    batch; dispatching three separate jits (dst placement, membership
    probe, merge) per batch is measurable overhead and three chances to
    leak an eager op.  This fuses dst = v+1 replica sets, the per-slot
    pending probe and the ``where`` merge into one traced body.  The
    cache key is exactly the static routing configuration -- re-begun
    windows, rollbacks and fresh ``LiveMigration`` objects at the same
    config all reuse the same compiled probe (shape changes of the
    pending view retrace inside jax's own cache, like every probe here).
    """
    fn = _ROUTE_CACHE.get(statics)
    if fn is not None:
        return fn
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import _place_replicas_fused_ref

    top_level, s_log2, max_draws, n_replicas = statics

    from repro.obs import get_ledger

    @jax.jit
    def route(ids, len32, node_of, ids_pad, src_pad, counts):
        get_ledger().incr("migrate.live.replica_route_traces")  # per TRACE
        u = ids.astype(jnp.uint32)
        dst = _place_replicas_fused_ref(
            u,
            len32,
            node_of,
            top_level=top_level,
            s_log2=s_log2,
            max_draws=max_draws,
            n_replicas=n_replicas,
            emit_nodes=True,
        )

        def per_slot(sorted_pad, src_vals, n):
            pos = jnp.searchsorted(sorted_pad, u, side="left")
            pos_c = jnp.minimum(pos, sorted_pad.shape[0] - 1)
            hit = (pos < n) & (sorted_pad[pos_c] == u)
            return hit, src_vals[pos_c]

        hit, src = jax.vmap(per_slot)(ids_pad, src_pad, counts)
        return jnp.where(hit.T, src.T, dst)

    _ROUTE_CACHE[statics] = route
    return route


@functools.cache
def _replica_member_fn():
    """Jitted per-slot membership + aligned-source gather: one vmapped
    sorted probe over the static R slots of the pending view."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def member(ids, ids_pad, src_pad, counts):
        u = ids.astype(jnp.uint32)

        def per_slot(sorted_pad, src_vals, n):
            pos = jnp.searchsorted(sorted_pad, u, side="left")
            pos_c = jnp.minimum(pos, sorted_pad.shape[0] - 1)
            hit = (pos < n) & (sorted_pad[pos_c] == u)
            return hit, src_vals[pos_c]

        hit, src = jax.vmap(per_slot)(ids_pad, src_pad, counts)
        return hit.T, src.T  # (batch, R)

    return member


class LiveMigration(DrainDriver):
    """One membership change served THROUGH its throttled drain.

    Wraps the three layers: the assembled plan (in ``state.plan``), the
    landed bitmap (``state``), and the budgeted scheduler (``mover``).
    The cluster table is already at v+1 when this object exists; readers
    must go through ``route``/``route_device`` until ``done``.
    """

    def __init__(self, engine, state: MigrationState, mover: ThrottledMover):
        self.engine = engine
        self.state = state
        self.mover = mover
        self.aborted = False
        # NO window-level ledger: this wrapper's _round/_pump_rounds call
        # the inner mover's PUBLIC verbs, whose DrainDriver hook already
        # emits each round exactly once.

    @classmethod
    def from_plan(
        cls,
        engine,
        plan,
        *,
        egress=None,
        ingress=None,
        clock=None,
        round_seconds: float = 1.0,
        ledger=None,
        metrics=None,
        bytes_per_row: int = 0,
    ) -> "LiveMigration":
        """Assemble the standard state + throttled mover around a plan (the
        one construction path every consumer shares)."""
        state = MigrationState(plan)
        mover = ThrottledMover(
            state,
            egress=egress,
            ingress=ingress,
            clock=clock,
            round_seconds=round_seconds,
            ledger=ledger,
            metrics=metrics,
            bytes_per_row=bytes_per_row,
        )
        return cls(engine, state, mover)

    # -- window state ---------------------------------------------------------

    @property
    def v_from(self) -> int:
        return self.state.plan.v_from

    @property
    def v_to(self) -> int:
        return self.state.plan.v_to

    @property
    def done(self) -> bool:
        return self.state.done

    def _check_live(self) -> None:
        if self.aborted:
            raise RuntimeError("migration was rolled back; drive the reverse one")

    # -- dual-version read rule ----------------------------------------------

    def route(self, datum_ids) -> np.ndarray:
        """ids -> the node that HOLDS each datum right now (host path).

        Only the (typically shrinking) pending subset pays the second
        placement under v; everything else is one placement under v+1.
        """
        self._check_live()
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        owner = self.engine.place_nodes_at(ids, self.v_to)
        pending = self.state.is_pending(ids)
        if pending.any():
            owner[pending] = self.engine.place_nodes_at(
                ids[pending], self.v_from
            )
        return owner

    def route_device(self, datum_ids):
        """Device-resident read rule: int32 node ids, zero host syncs.

        The pending-set device view is refreshed on the control path
        (``round``/``pump`` mark rows landed; the first ``route_device``
        after that pays the one upload) -- call once outside any transfer
        guard after each round, then serve freely."""
        self._check_live()
        import jax.numpy as jnp

        _, src, dst = self.engine.diff_nodes_device(
            datum_ids, self.v_from, self.v_to
        )
        sorted_pad, n = self.state.pending_device()
        pending = _member_fn()(jnp.asarray(datum_ids), sorted_pad, n)
        return jnp.where(pending, src, dst)

    # -- per-slot replica read rule (DESIGN.md section 10) --------------------

    @property
    def n_replicas(self) -> int:
        return self.state.plan.n_replicas

    def route_replicas(self, datum_ids) -> np.ndarray:
        """ids -> the (batch, R) replica sets that HOLD each datum now.

        Slot r serves its vacated v-side source while its copy is pending
        and the v+1 owner after; non-moving slots hold the datum
        throughout.  Every returned set is pairwise-distinct: pending
        sources are vacated (lost) nodes, which by construction are not
        members of the v+1 set, and distinct slots pair with distinct
        sources (the rank-matched alignment).
        """
        self._check_live()
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        owner = self.engine.place_replica_nodes_at(ids, self.v_to, self.n_replicas)
        pending, src = self.state.pending_replicas(ids)
        return np.where(pending, src, owner)

    def route_replicas_device(self, datum_ids):
        """Device-resident ``route_replicas``: (batch, R) int32, zero host
        syncs after the per-round control-path refresh (the per-slot
        pending view uploads once per round, like ``route_device``).

        The whole rule -- v+1 replica placement, per-slot pending probe,
        merge -- runs as ONE cached jit (``_fused_replica_route``), so the
        batched serving driver pays a single dispatch per batch and
        repeated batches never retrace (``probe_trace_count`` tripwire)."""
        self._check_live()
        import jax.numpy as jnp

        art = self.engine._device_artifact_for(self.v_to, "asura")
        params = self.engine.params
        statics = (art.top_level, params.s_log2, params.max_draws, self.n_replicas)
        ids_pad, src_pad, counts = self.state.pending_replicas_device()
        return _fused_replica_route(statics)(
            jnp.asarray(datum_ids), art.len32_dev, art.node_of_dev,
            ids_pad, src_pad, counts,
        )

    # -- drain control (round/pump/run from the shared DrainDriver loop) ------

    def _advance(self, fn):
        self._check_live()
        return fn()

    def _round(self) -> dict[tuple[int, int], int]:
        return self.mover.round()

    def _pump_rounds(self) -> list[dict[tuple[int, int], int]]:
        # delegate so clock accounting lives in the mover alone (mixing
        # mover.pump() and migration.pump() must not double-run periods)
        return self.mover.pump()

    def round_block(self, k: int) -> list[dict[tuple[int, int], int]]:
        """k budgeted rounds in ONE device dispatch (the mover's
        scan-fused round block); returns the k per-round matrices.  The
        mover's public verb already ledger-emits each round exactly once,
        so this wrapper only adds the liveness guard."""
        self._check_live()
        return self.mover.round_block(k)

    def _pending_desc(self) -> str:
        return f"{self.state.n_pending} rows pending"

    # -- rollback -------------------------------------------------------------

    def rollback(self) -> "LiveMigration":
        """Reverse a half-landed migration; returns the reverse migration.

        The reverse plan is the LANDED rows with src/dst and v_from/v_to
        swapped (unlanded rows never moved -- nothing to reverse).  This
        object becomes inert; drive and route through the returned one.
        Budgets swap roles with the flow direction: the forward drain's
        per-node ingress caps bind the reverse drain's egress and vice
        versa, so the node the throttle was protecting stays protected.
        Both versions stay in the artifact LRU, so the flap re-uploads
        nothing.  Once the reverse drain completes, all data is back at
        its v owner and the caller may revert the membership change
        itself (e.g. ``cluster.remove_node`` of the just-added node) --
        segment correspondences never change (paper rule 2), so the
        reverted table places identically to v.  Consumers that maintain
        side state per owner should roll it back too
        (``ElasticCoordinator.rollback_live`` does).
        """
        self._check_live()
        if getattr(self, "membership_event", None) is not None and not getattr(
            self, "_coordinator_rollback", False
        ):
            # A coordinator-owned migration carries side state (owner table,
            # membership) that a bare reversal would silently desync.
            raise RuntimeError(
                "this migration belongs to an ElasticCoordinator; use "
                "coordinator.rollback_live(migration)"
            )
        from .planner import MigrationPlan

        plan, landed = self.state.plan, self.state.landed
        reverse_plan = MigrationPlan(
            v_from=plan.v_to,
            v_to=plan.v_from,
            ids=plan.ids[landed],
            src=plan.dst[landed],
            dst=plan.src[landed],
            index=plan.index[landed],
            n_scanned=plan.n_scanned,
            n_replicas=plan.n_replicas,
            # slots index the plan's DESTINATION set; the reverse drains
            # back into the original v set, so slot/src_slot swap along
            # with src/dst (DESIGN.md section 10).
            slot=plan.src_slot[landed],
            src_slot=plan.slot[landed],
        )
        self.aborted = True
        mover = self.mover
        reverse = LiveMigration.from_plan(
            self.engine,
            reverse_plan,
            egress=mover.ingress,  # reversed flows: receive caps now bind sends
            ingress=mover.egress,
            clock=mover.clock,
            round_seconds=mover.round_seconds,
            ledger=mover.ledger,
            metrics=mover.metrics,
            bytes_per_row=mover.bytes_per_row,
        )
        tracked = getattr(self, "tracked_rows", None)
        if tracked is not None:
            # consumer side-state mapping rides along (plan rows = landed)
            reverse.tracked_rows = tracked[landed]
        return reverse
