"""Migration layer 3: the dual-version serving window.

While a plan drains, the system is BETWEEN versions: some data already
sits at its v+1 owner, the rest still at its v owner.  ``LiveMigration``
owns that window and gives readers one total rule (DESIGN.md section 8):

    route(id) = v   owner  if id's move is still pending,
                v+1 owner  otherwise (landed, or never had to move)

Equivalently: route to the v+1 owner iff the id's move has landed.  The
"pending" formulation is what makes ROLLBACK free: reversing a
half-landed migration is just a new LiveMigration whose plan is the
landed rows with src/dst swapped and v_from/v_to swapped -- unlanded
rows of the original never moved, so under the reversed rule they fall
into the "not in plan -> v_to(reverse) = v(original) owner" case, which
is exactly where they physically are.

Both versions' placements come from the engine's artifact LRU (no table
re-upload during the window, no matter how often the router flaps) and
``route_device`` keeps the whole rule on device: the fused dual-table
diff kernel supplies both owners, a sorted-membership probe against the
pending set supplies the landed bit, and one ``where`` merges them --
zero host syncs after the per-round control-path update.
"""

from __future__ import annotations

import functools

import numpy as np

from .mover import MigrationState, ThrottledMover


@functools.cache
def _member_fn():
    """Jitted sorted-set membership (lazy: no jax import on the host path)."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def member(ids, sorted_pad, n):
        pos = jnp.searchsorted(sorted_pad, ids.astype(jnp.uint32), side="left")
        pos_c = jnp.minimum(pos, sorted_pad.shape[0] - 1)
        return (pos < n) & (sorted_pad[pos_c] == ids)

    return member


class LiveMigration:
    """One membership change served THROUGH its throttled drain.

    Wraps the three layers: the assembled plan (in ``state.plan``), the
    landed bitmap (``state``), and the budgeted scheduler (``mover``).
    The cluster table is already at v+1 when this object exists; readers
    must go through ``route``/``route_device`` until ``done``.
    """

    def __init__(self, engine, state: MigrationState, mover: ThrottledMover):
        self.engine = engine
        self.state = state
        self.mover = mover
        self.aborted = False

    @classmethod
    def from_plan(
        cls,
        engine,
        plan,
        *,
        egress=None,
        ingress=None,
        clock=None,
        round_seconds: float = 1.0,
    ) -> "LiveMigration":
        """Assemble the standard state + throttled mover around a plan (the
        one construction path every consumer shares)."""
        state = MigrationState(plan)
        mover = ThrottledMover(
            state,
            egress=egress,
            ingress=ingress,
            clock=clock,
            round_seconds=round_seconds,
        )
        return cls(engine, state, mover)

    # -- window state ---------------------------------------------------------

    @property
    def v_from(self) -> int:
        return self.state.plan.v_from

    @property
    def v_to(self) -> int:
        return self.state.plan.v_to

    @property
    def done(self) -> bool:
        return self.state.done

    def _check_live(self) -> None:
        if self.aborted:
            raise RuntimeError("migration was rolled back; drive the reverse one")

    # -- dual-version read rule ----------------------------------------------

    def route(self, datum_ids) -> np.ndarray:
        """ids -> the node that HOLDS each datum right now (host path).

        Only the (typically shrinking) pending subset pays the second
        placement under v; everything else is one placement under v+1.
        """
        self._check_live()
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        owner = self.engine.place_nodes_at(ids, self.v_to)
        pending = self.state.is_pending(ids)
        if pending.any():
            owner[pending] = self.engine.place_nodes_at(
                ids[pending], self.v_from
            )
        return owner

    def route_device(self, datum_ids):
        """Device-resident read rule: int32 node ids, zero host syncs.

        The pending-set device view is refreshed on the control path
        (``round``/``pump`` mark rows landed; the first ``route_device``
        after that pays the one upload) -- call once outside any transfer
        guard after each round, then serve freely."""
        self._check_live()
        import jax.numpy as jnp

        _, src, dst = self.engine.diff_nodes_device(
            datum_ids, self.v_from, self.v_to
        )
        sorted_pad, n = self.state.pending_device()
        pending = _member_fn()(jnp.asarray(datum_ids), sorted_pad, n)
        return jnp.where(pending, src, dst)

    # -- drain control --------------------------------------------------------

    def round(self) -> dict[tuple[int, int], int]:
        """One throttled round; returns its (src, dst) movement matrix."""
        self._check_live()
        return self.mover.round()

    def pump(self) -> list[dict[tuple[int, int], int]]:
        """Clock-driven advance (see ``ThrottledMover.pump``)."""
        self._check_live()
        return self.mover.pump()

    def run(self, max_rounds: int = 100_000) -> list[dict[tuple[int, int], int]]:
        self._check_live()
        return self.mover.run(max_rounds)

    # -- rollback -------------------------------------------------------------

    def rollback(self) -> "LiveMigration":
        """Reverse a half-landed migration; returns the reverse migration.

        The reverse plan is the LANDED rows with src/dst and v_from/v_to
        swapped (unlanded rows never moved -- nothing to reverse).  This
        object becomes inert; drive and route through the returned one.
        Budgets swap roles with the flow direction: the forward drain's
        per-node ingress caps bind the reverse drain's egress and vice
        versa, so the node the throttle was protecting stays protected.
        Both versions stay in the artifact LRU, so the flap re-uploads
        nothing.  Once the reverse drain completes, all data is back at
        its v owner and the caller may revert the membership change
        itself (e.g. ``cluster.remove_node`` of the just-added node) --
        segment correspondences never change (paper rule 2), so the
        reverted table places identically to v.  Consumers that maintain
        side state per owner should roll it back too
        (``ElasticCoordinator.rollback_live`` does).
        """
        self._check_live()
        if getattr(self, "membership_event", None) is not None and not getattr(
            self, "_coordinator_rollback", False
        ):
            # A coordinator-owned migration carries side state (owner table,
            # membership) that a bare reversal would silently desync.
            raise RuntimeError(
                "this migration belongs to an ElasticCoordinator; use "
                "coordinator.rollback_live(migration)"
            )
        from .planner import MigrationPlan

        plan, landed = self.state.plan, self.state.landed
        reverse_plan = MigrationPlan(
            v_from=plan.v_to,
            v_to=plan.v_from,
            ids=plan.ids[landed],
            src=plan.dst[landed],
            dst=plan.src[landed],
            index=plan.index[landed],
            n_scanned=plan.n_scanned,
        )
        self.aborted = True
        mover = self.mover
        reverse = LiveMigration.from_plan(
            self.engine,
            reverse_plan,
            egress=mover.ingress,  # reversed flows: receive caps now bind sends
            ingress=mover.egress,
            clock=mover.clock,
            round_seconds=mover.round_seconds,
        )
        tracked = getattr(self, "tracked_rows", None)
        if tracked is not None:
            # consumer side-state mapping rides along (plan rows = landed)
            reverse.tracked_rows = tracked[landed]
        return reverse
