from .sharded import AsuraCheckpointStore, CheckpointManager, StoreMigration

__all__ = ["AsuraCheckpointStore", "CheckpointManager", "StoreMigration"]
