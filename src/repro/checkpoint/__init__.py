from .sharded import AsuraCheckpointStore, CheckpointManager

__all__ = ["AsuraCheckpointStore", "CheckpointManager"]
