"""ASURA-placed, replicated, async checkpointing.

Checkpoint model: the train state is flattened to leaves; each leaf is split
into fixed-size chunks; each chunk gets a stable datum id
hash(step, leaf_index, chunk_index).  ASURA places every chunk on R distinct
storage nodes (paper section 5.A replication) -- so

  * there is NO manifest mapping chunks to nodes: any reader recomputes the
    placement from the O(N) segment table (algorithm management),
  * the system tolerates up to R-1 storage-node losses for every chunk,
  * when a storage node dies, exactly the chunks it held are re-replicated
    (optimal data movement, paper section 2.A), chosen via REMOVE NUMBERS
    without recomputing every chunk's placement (section 2.D),
  * adding storage capacity rebalances minimally (ADDITION NUMBER path).

``StorageNode`` is an in-memory stand-in for a storage daemon; the I/O layer
is deliberately pluggable (the placement logic is the paper's contribution).
Async saves run on a thread and are awaited by ``wait()`` -- checkpoint
writes overlap the next training step.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.core import Cluster
from repro.core.asura import remove_numbers
from repro.core.rng import fmix32_scalar

CHUNK_BYTES = 1 << 20  # 1 MiB chunks, the paper's example datum unit


def chunk_id(step: int, leaf_idx: int, chunk_idx: int) -> int:
    return fmix32_scalar(
        fmix32_scalar(step * 0x9E3779B9 + leaf_idx) ^ (chunk_idx * 0x85EBCA77)
    )


@dataclasses.dataclass
class StorageNode:
    node_id: int
    capacity: float
    blobs: dict[int, bytes] = dataclasses.field(default_factory=dict)
    alive: bool = True

    def put(self, key: int, blob: bytes) -> None:
        if not self.alive:
            raise IOError(f"node {self.node_id} is down")
        self.blobs[key] = blob

    def get(self, key: int) -> bytes:
        if not self.alive:
            raise IOError(f"node {self.node_id} is down")
        return self.blobs[key]

    def used_bytes(self) -> int:
        return sum(len(b) for b in self.blobs.values())


class AsuraCheckpointStore:
    """A cluster of storage nodes addressed purely by the ASURA table."""

    def __init__(self, capacities: dict[int, float], n_replicas: int = 3):
        self.cluster = Cluster()
        self.nodes: dict[int, StorageNode] = {}
        for nid, cap in capacities.items():
            self.cluster.add_node(nid, cap)
            self.nodes[nid] = StorageNode(nid, cap)
        self.n_replicas = n_replicas
        # Chunk placement runs through the cluster's PlacementEngine: save /
        # restore / repair issue many replica lookups against one cached
        # table artifact per membership version (no per-call table prep).
        self.engine = self.cluster.engine

    # -- placement ---------------------------------------------------------

    def replicas_for(self, keys: np.ndarray) -> np.ndarray:
        return self.engine.place_replica_nodes(
            np.asarray(keys, dtype=np.uint32), self.n_replicas
        )

    def replicas_for_device(self, keys):
        """(keys, R) replica node ids as a DEVICE array, zero host syncs.

        For device-chained consumers (e.g. diffing placements across a
        membership change, or sharding device-resident key streams): the
        placement, tail resolution and node gather all stay on device."""
        return self.engine.place_replica_nodes_device(keys, self.n_replicas)

    # -- chunk I/O ----------------------------------------------------------

    def put_chunks(self, keys: np.ndarray, blobs: list[bytes]) -> None:
        placements = self.replicas_for(keys)
        for key, blob, nodes in zip(keys, blobs, placements):
            for nid in nodes:
                self.nodes[int(nid)].put(int(key), blob)

    def get_chunk(self, key: int) -> bytes:
        nodes = self.replicas_for(np.array([key], dtype=np.uint32))[0]
        errors = []
        for nid in nodes:  # primary first, replicas on failure
            node = self.nodes[int(nid)]
            if not node.alive:
                errors.append(f"node {nid} down")
                continue
            try:
                return node.get(int(key))
            except KeyError:
                errors.append(f"node {nid} missing chunk")
        raise IOError(f"chunk {key} unreadable: {errors}")

    # -- elasticity / failure ----------------------------------------------

    def fail_node(self, node_id: int) -> None:
        self.nodes[node_id].alive = False

    def remove_node_and_repair(self, node_id: int) -> int:
        """Remove a node; re-replicate exactly the chunks it held.

        Uses REMOVE NUMBERS (paper section 2.D): a chunk needs repair iff one
        of its remove numbers is a segment of the removed node.  Returns the
        number of chunk copies moved (provably minimal)."""
        victim_segments = set(self.cluster.nodes[node_id].segments)
        lengths = self.cluster.seg_lengths()
        node_of = self.cluster.seg_to_node()
        # collect every stored key (any surviving replica knows its blobs)
        all_keys: dict[int, bytes] = {}
        for node in self.nodes.values():
            if node.node_id != node_id and node.alive:
                all_keys.update(node.blobs)
        affected = [
            key
            for key in all_keys
            if victim_segments
            & set(remove_numbers(key, lengths, node_of, self.n_replicas))
        ]
        self.cluster.remove_node(node_id)
        dead = self.nodes.pop(node_id)
        dead.alive = False
        moved = 0
        for key in affected:
            placements = self.replicas_for(np.array([key], dtype=np.uint32))[0]
            blob = all_keys[key]
            for nid in placements:
                node = self.nodes[int(nid)]
                # other down-but-not-yet-removed nodes get their copies when
                # their own removal/repair runs
                if node.alive and int(key) not in node.blobs:
                    node.put(int(key), blob)
                    moved += 1
        return moved

    def add_node(self, node_id: int, capacity: float) -> int:
        """Add storage; migrate exactly the chunks the new node wins."""
        all_keys: dict[int, bytes] = {}
        for node in self.nodes.values():
            all_keys.update(node.blobs)
        keys = np.fromiter(all_keys, dtype=np.uint32, count=len(all_keys))
        device = self.engine.backend != "numpy"
        if device and keys.size:
            # Chain both placement sweeps on device; sync the rows once.
            import jax.numpy as jnp

            keys_dev = jnp.asarray(keys)
            before_dev = self.replicas_for_device(keys_dev)
            before = np.asarray(before_dev)
        else:
            before = (
                self.replicas_for(keys)
                if keys.size
                else np.empty((0, self.n_replicas))
            )
        self.cluster.add_node(node_id, capacity)
        self.nodes[node_id] = StorageNode(node_id, capacity)
        moved = 0
        if keys.size:
            if device:
                after = np.asarray(self.replicas_for_device(keys_dev))
            else:
                after = self.replicas_for(keys)
            for key, b_row, a_row in zip(keys, before, after):
                if set(b_row.tolist()) == set(a_row.tolist()):
                    continue
                blob = all_keys[int(key)]
                a_set = set(int(x) for x in a_row)
                for nid in a_set:
                    node = self.nodes[nid]
                    if node.alive and int(key) not in node.blobs:
                        node.put(int(key), blob)
                        moved += 1
                # GC copies superseded by the new placement (reclaim capacity)
                for nid in set(int(x) for x in b_row) - a_set:
                    self.nodes[nid].blobs.pop(int(key), None)
        return moved


class CheckpointManager:
    """Save/restore jax pytrees against an AsuraCheckpointStore."""

    def __init__(self, store: AsuraCheckpointStore):
        self.store = store
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.saved_steps: list[int] = []

    # -- save ----------------------------------------------------------------

    def _chunks_of(self, step: int, tree: Any):
        leaves = jax.tree.leaves(tree)
        for li, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            raw = arr.tobytes()
            n = max(1, -(-len(raw) // CHUNK_BYTES))
            for ci in range(n):
                blob = raw[ci * CHUNK_BYTES : (ci + 1) * CHUNK_BYTES]
                yield chunk_id(step, li, ci), blob

    def save(self, step: int, tree: Any) -> None:
        keys, blobs = [], []
        for key, blob in self._chunks_of(step, tree):
            keys.append(key)
            blobs.append(blob)
        self.store.put_chunks(np.asarray(keys, dtype=np.uint32), blobs)
        self.saved_steps.append(step)

    def save_async(self, step: int, tree: Any) -> None:
        """Snapshot to host, then write on a thread (overlaps training)."""
        self.wait()
        snapshot = jax.tree.map(np.asarray, tree)

        def work():
            try:
                self.save(step, snapshot)
            except BaseException as e:  # surfaced by wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore --------------------------------------------------------------

    def restore(self, step: int, like: Any) -> Any:
        """Rebuild a pytree shaped like ``like`` from the store."""
        leaves, treedef = jax.tree.flatten(like)
        out = []
        for li, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            raw = arr.tobytes()
            n = max(1, -(-len(raw) // CHUNK_BYTES))
            parts = [self.store.get_chunk(chunk_id(step, li, ci)) for ci in range(n)]
            buf = b"".join(parts)
            out.append(np.frombuffer(buf, dtype=arr.dtype).reshape(arr.shape))
        return treedef.unflatten(out)
