"""ASURA-placed, replicated, async checkpointing.

Checkpoint model: the train state is flattened to leaves; each leaf is split
into fixed-size chunks; each chunk gets a stable datum id
hash(step, leaf_index, chunk_index).  ASURA places every chunk on R distinct
storage nodes (paper section 5.A replication) -- so

  * there is NO manifest mapping chunks to nodes: any reader recomputes the
    placement from the O(N) segment table (algorithm management),
  * the system tolerates up to R-1 storage-node losses for every chunk,
  * when a storage node dies, exactly the chunks it held are re-replicated
    (optimal data movement, paper section 2.A), chosen via REMOVE NUMBERS
    without recomputing every chunk's placement (section 2.D),
  * adding storage capacity rebalances minimally (ADDITION NUMBER path).

``StorageNode`` is an in-memory stand-in for a storage daemon; the I/O layer
is deliberately pluggable (the placement logic is the paper's contribution).
Async saves run on a thread and are awaited by ``wait()`` -- checkpoint
writes overlap the next training step.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.core import Cluster
from repro.core.rng import fmix32_scalar
from repro.migrate import DrainDriver

CHUNK_BYTES = 1 << 20  # 1 MiB chunks, the paper's example datum unit


def chunk_id(step: int, leaf_idx: int, chunk_idx: int) -> int:
    return fmix32_scalar(
        fmix32_scalar(step * 0x9E3779B9 + leaf_idx) ^ (chunk_idx * 0x85EBCA77)
    )


@dataclasses.dataclass
class StorageNode:
    node_id: int
    capacity: float
    blobs: dict[int, bytes] = dataclasses.field(default_factory=dict)
    alive: bool = True

    def put(self, key: int, blob: bytes) -> None:
        if not self.alive:
            raise IOError(f"node {self.node_id} is down")
        self.blobs[key] = blob

    def get(self, key: int) -> bytes:
        if not self.alive:
            raise IOError(f"node {self.node_id} is down")
        return self.blobs[key]

    def used_bytes(self) -> int:
        return sum(len(b) for b in self.blobs.values())


class AsuraCheckpointStore:
    """A cluster of storage nodes addressed purely by the ASURA table."""

    def __init__(self, capacities: dict[int, float], n_replicas: int = 3):
        self.cluster = Cluster()
        self.nodes: dict[int, StorageNode] = {}
        for nid, cap in capacities.items():
            self.cluster.add_node(nid, cap)
            self.nodes[nid] = StorageNode(nid, cap)
        self.n_replicas = n_replicas
        # Chunk placement runs through the cluster's PlacementEngine: save /
        # restore / repair issue many replica lookups against one cached
        # table artifact per membership version (no per-call table prep).
        self.engine = self.cluster.engine
        self._migration: StoreMigration | None = None  # live rebalance window

    # -- placement ---------------------------------------------------------

    def replicas_for(self, keys: np.ndarray) -> np.ndarray:
        return self.engine.place_replica_nodes(
            np.asarray(keys, dtype=np.uint32), self.n_replicas
        )

    def replicas_for_device(self, keys):
        """(keys, R) replica node ids as a DEVICE array, zero host syncs.

        For device-chained consumers (e.g. diffing placements across a
        membership change, or sharding device-resident key streams): the
        placement, tail resolution and node gather all stay on device."""
        return self.engine.place_replica_nodes_device(keys, self.n_replicas)

    def _all_blobs(self) -> dict[int, bytes]:
        """Every stored (key, blob) across the live nodes."""
        all_keys: dict[int, bytes] = {}
        for node in self.nodes.values():
            all_keys.update(node.blobs)
        return all_keys

    def _replica_rows(self, keys: np.ndarray, keys_dev=None) -> np.ndarray:
        """Host (keys, R) replica sweep, chained on device when available
        (one sync for the whole sweep instead of per-key work)."""
        if keys.size == 0:
            return np.empty((0, self.n_replicas), dtype=np.int64)
        if keys_dev is not None:
            return np.asarray(self.replicas_for_device(keys_dev)).astype(np.int64)
        return self.replicas_for(keys)

    # -- chunk I/O ----------------------------------------------------------

    def put_chunks(self, keys: np.ndarray, blobs: list[bytes]) -> None:
        placements = self.replicas_for(keys)
        for key, blob, nodes in zip(keys, blobs, placements):
            if self._migration is not None:
                # Write through the migration window: a pending chunk must
                # be overwritten where READERS are routed (its mixed-version
                # replica set) -- the fresh blob then rides the landing copy
                # to the v+1 owners (``StoreMigration._land`` prefers the
                # live copy, and the refreshed snapshot keeps even the
                # all-sources-died fallback from resurrecting stale bytes).
                row = self._migration.read_row(int(key))
                if row is not None:
                    nodes = row
                    self._migration._blobs[int(key)] = blob
            for nid in nodes:
                # a served set may still name a REMOVED node mid-repair
                # (its pending slots); skip it -- the fresh blob rides the
                # landing copy.  Dead-but-registered nodes still raise.
                node = self.nodes.get(int(nid))
                if node is not None:
                    node.put(int(key), blob)

    def get_chunk(self, key: int) -> bytes:
        nodes = None
        if self._migration is not None:
            # Migration-window read rule (DESIGN.md sections 8, 10): each
            # replica SLOT of a moving chunk is read from its v-side source
            # until its copy lands, from its v+1 owner after -- the set
            # that actually holds it, mixed-version mid-drain.
            nodes = self._migration.read_row(int(key))
        if nodes is None:
            nodes = self.replicas_for(np.array([key], dtype=np.uint32))[0]
        errors = []
        for nid in nodes:  # primary first, replicas on failure
            node = self.nodes.get(int(nid))
            if node is None or not node.alive:
                errors.append(f"node {nid} down")
                continue
            try:
                return node.get(int(key))
            except KeyError:
                errors.append(f"node {nid} missing chunk")
        raise IOError(f"chunk {key} unreadable: {errors}")

    # -- elasticity / failure ----------------------------------------------

    def fail_node(self, node_id: int) -> None:
        self.nodes[node_id].alive = False

    def _check_no_migration(self) -> None:
        """Membership must not mutate under a live rebalance window -- the
        window's before/after snapshots would no longer describe reality
        (same single-drain rule as ``ElasticCoordinator``)."""
        if self._migration is not None and not self._migration.done:
            raise RuntimeError(
                "a store migration is in flight; drain it before the next "
                "membership event"
            )

    def _affected_by_removal(self, keys: np.ndarray, node_id: int) -> np.ndarray:
        """Keys whose replica set includes the victim, via one vectorized
        REMOVE-NUMBER sweep (section 2.D: a chunk is affected iff one of
        its remove numbers names a victim segment) -- the engine-path
        ``remove_numbers_batch``, not a per-key scalar trace."""
        if keys.size == 0:
            return keys
        victim_segments = np.asarray(
            sorted(self.cluster.nodes[node_id].segments), dtype=np.int64
        )
        rn = self.engine.remove_numbers_batch(keys, self.n_replicas)
        return keys[np.isin(rn, victim_segments).any(axis=1)]

    def remove_node_and_repair(self, node_id: int) -> int:
        """Remove a node; re-replicate exactly the chunks it held.

        Uses REMOVE NUMBERS (paper section 2.D): a chunk needs repair iff
        one of its remove numbers is a segment of the removed node --
        computed for the whole key population in one vectorized
        ``remove_numbers_batch`` sweep.  Returns the number of chunk copies
        moved (provably minimal).  ``begin_remove_node`` is the THROTTLED
        variant (repair as a live replica migration)."""
        self._check_no_migration()
        # collect every stored key (any surviving replica knows its blobs)
        all_keys: dict[int, bytes] = {}
        for node in self.nodes.values():
            if node.node_id != node_id and node.alive:
                all_keys.update(node.blobs)
        keys = np.fromiter(all_keys, dtype=np.uint32, count=len(all_keys))
        affected = self._affected_by_removal(keys, node_id)
        self.cluster.remove_node(node_id)
        dead = self.nodes.pop(node_id)
        dead.alive = False
        moved = 0
        if affected.size:
            placements = self.replicas_for(affected)  # one vectorized sweep
            for key, row in zip(affected, placements):
                blob = all_keys[int(key)]
                for nid in row:
                    node = self.nodes[int(nid)]
                    # other down-but-not-yet-removed nodes get their copies
                    # when their own removal/repair runs
                    if node.alive and int(key) not in node.blobs:
                        node.put(int(key), blob)
                        moved += 1
        return moved

    def _begin_migration(
        self,
        plan,
        all_keys,
        *,
        egress,
        ingress,
        clock,
        round_seconds,
        ledger=None,
        bytes_per_row=0,
    ) -> "StoreMigration":
        from repro.migrate import LiveMigration

        live = LiveMigration.from_plan(
            self.engine,
            plan,
            egress=egress,
            ingress=ingress,
            clock=clock,
            round_seconds=round_seconds,
            ledger=ledger,
            bytes_per_row=bytes_per_row,
        )
        self._migration = StoreMigration(self, live, all_keys)
        return self._migration

    def begin_add_node(
        self,
        node_id: int,
        capacity: float,
        *,
        egress=None,
        ingress=None,
        clock=None,
        round_seconds: float = 1.0,
        ledger=None,
    ) -> "StoreMigration":
        """Add storage as a LIVE migration: the same minimal chunk set as
        ``add_node``, but blob copies drain in bandwidth-budgeted rounds
        while ``get_chunk`` reads through the dual-version rule.

        The plan is the PER-SLOT replica plan (``plan_replicas``, DESIGN.md
        section 10): one row per replica copy that actually changes owner,
        with the vacated v-side node as its source -- so ingress/egress
        budgets bind on the nodes doing each transfer and the movement
        matrices account every copy, not one flow per chunk.  The add-node
        ADDITION-NUMBER prefilter (R-replica trace) shrinks the diff set.
        Drive the returned ``StoreMigration`` (``round``/``pump``/``run``);
        the store detaches it automatically once drained.  A ``ledger``
        gets one ``migrate.round`` event per drained round with CHUNK_BYTES
        per-row byte accounting."""
        from repro.migrate import MigrationPlanner

        self._check_no_migration()
        all_keys = self._all_blobs()
        keys = np.fromiter(all_keys, dtype=np.uint32, count=len(all_keys))
        self.engine.artifact()  # pin the v table before mutating
        v_from = self.cluster.version
        new_segs = self.cluster.add_node(node_id, capacity)
        self.nodes[node_id] = StorageNode(node_id, capacity)
        plan = MigrationPlanner(self.engine, ledger=ledger).plan_replicas(
            keys,
            v_from,
            self.cluster.version,
            self.n_replicas,
            max_new_seg=max(new_segs) if new_segs else None,
        )
        return self._begin_migration(
            plan,
            all_keys,
            egress=egress,
            ingress=ingress,
            clock=clock,
            round_seconds=round_seconds,
            ledger=ledger,
            bytes_per_row=CHUNK_BYTES,
        )

    def begin_remove_node(
        self,
        node_id: int,
        *,
        egress=None,
        ingress=None,
        clock=None,
        round_seconds: float = 1.0,
        ledger=None,
    ) -> "StoreMigration":
        """Remove (or repair a failed) node as a LIVE migration.

        The throttled variant of ``remove_node_and_repair``: exactly the
        victim's replica mass re-replicates -- a per-slot replica plan over
        the affected keys (one vectorized REMOVE-NUMBER sweep picks them)
        whose every row sources at the victim -- in bandwidth-budgeted
        rounds, while ``get_chunk`` keeps reading through the window: a
        pending slot still names the victim, and the surviving R-1 replicas
        serve it via the fall-back read, so restores stay bit-identical
        throughout the degraded window (tested)."""
        from repro.migrate import MigrationPlanner

        self._check_no_migration()
        all_keys = self._all_blobs()
        keys = np.fromiter(all_keys, dtype=np.uint32, count=len(all_keys))
        self.engine.artifact()  # pin the v table before mutating
        v_from = self.cluster.version
        affected = self._affected_by_removal(keys, node_id)
        self.cluster.remove_node(node_id)
        dead = self.nodes.pop(node_id)
        dead.alive = False
        plan = MigrationPlanner(self.engine, ledger=ledger).plan_replicas(
            affected, v_from, self.cluster.version, self.n_replicas
        )
        return self._begin_migration(
            plan,
            all_keys,
            egress=egress,
            ingress=ingress,
            clock=clock,
            round_seconds=round_seconds,
            ledger=ledger,
            bytes_per_row=CHUNK_BYTES,
        )

    def add_node(self, node_id: int, capacity: float) -> int:
        """Add storage; migrate exactly the chunks the new node wins."""
        self._check_no_migration()
        all_keys = self._all_blobs()
        keys = np.fromiter(all_keys, dtype=np.uint32, count=len(all_keys))
        keys_dev = None
        if self.engine.backend != "numpy" and keys.size:
            # Chain both placement sweeps on device; sync the rows once.
            import jax.numpy as jnp

            keys_dev = jnp.asarray(keys)
        before = self._replica_rows(keys, keys_dev)
        self.cluster.add_node(node_id, capacity)
        self.nodes[node_id] = StorageNode(node_id, capacity)
        moved = 0
        if keys.size:
            after = self._replica_rows(keys, keys_dev)
            for key, b_row, a_row in zip(keys, before, after):
                if set(b_row.tolist()) == set(a_row.tolist()):
                    continue
                blob = all_keys[int(key)]
                a_set = set(int(x) for x in a_row)
                for nid in a_set:
                    node = self.nodes[nid]
                    if node.alive and int(key) not in node.blobs:
                        node.put(int(key), blob)
                        moved += 1
                # GC copies superseded by the new placement (reclaim capacity)
                for nid in set(int(x) for x in b_row) - a_set:
                    self.nodes[nid].blobs.pop(int(key), None)
        return moved


class StoreMigration(DrainDriver):
    """A live storage rebalance: throttled PER-SLOT blob copies +
    read-through (DESIGN.md section 10).

    Wraps a ``LiveMigration`` over a per-slot replica plan: each row is one
    replica copy ``(key, slot, src, dst)``.  Each round the mover lands a
    budgeted batch of rows; every newly landed row copies its blob to the
    row's destination and garbage-collects the vacated source copy once
    the destination actually holds it (capacity is reclaimed
    incrementally, and a destination that died mid-migration never costs
    the surviving copies -- repair reconciles it later).  ``read_row`` is
    ``get_chunk``'s window rule: the mixed-version replica set that holds
    the key right now (``LiveMigration.route_replicas``), ``None`` for
    unaffected keys.  round/pump/run come from the shared ``DrainDriver``
    loop; the landing hook rides ``_advance`` so no verb can skip it.
    """

    def __init__(self, store, live, blobs):
        self.store = store
        self.live = live
        self._window_ids = np.unique(live.state.plan.ids)  # sorted
        self._served_rows = None  # per-round cache of the window's sets
        self._blobs = blobs  # key -> blob snapshot, refreshed by put_chunks
        self.copies_moved = 0

    @property
    def done(self) -> bool:
        return self.live.done

    def _pending_desc(self) -> str:
        return f"{self.live.state.n_pending} rows pending"

    def read_row(self, key: int):
        pos = int(np.searchsorted(self._window_ids, np.uint32(key)))
        if pos >= len(self._window_ids) or int(self._window_ids[pos]) != int(key):
            return None
        if self._served_rows is None:
            # One vectorized replica-route sweep per ROUND for the whole
            # window (served sets only change when rows land, which
            # invalidates this cache) -- per-key reads are then O(log n).
            self._served_rows = self.live.route_replicas(self._window_ids)
        return self._served_rows[pos]

    def _land(self, rows: np.ndarray) -> None:
        plan = self.live.state.plan
        for row in rows:
            key = int(plan.ids[row])
            src = int(plan.src[row])
            dst = int(plan.dst[row])
            # Prefer the live copy at the vacated source (the chunk may
            # have been overwritten mid-migration -- window writes land on
            # the serving set, which includes the source while pending);
            # the put_chunks-refreshed snapshot is the fallback.
            blob = self._blobs.get(key)
            snode = self.store.nodes.get(src)
            if snode is not None and snode.alive and key in snode.blobs:
                blob = snode.blobs[key]
            dnode = self.store.nodes.get(dst)  # tolerate removed nodes
            if (
                blob is not None
                and dnode is not None
                and dnode.alive
                and key not in dnode.blobs
            ):
                dnode.put(key, blob)
                self.copies_moved += 1
            # GC the vacated copy ONLY once a LIVE destination holds the
            # chunk -- a dead destination's copy is unreadable and must not
            # cost the surviving one.
            if (
                snode is not None
                and dnode is not None
                and dnode.alive
                and key in dnode.blobs
            ):
                snode.blobs.pop(key, None)

    def _advance(self, fn) -> list[dict[tuple[int, int], int]]:
        pre = self.live.state.landed.copy()
        matrices = fn()
        newly = np.nonzero(self.live.state.landed & ~pre)[0]
        if newly.size:
            self._served_rows = None  # landed bits moved the read rule
        self._land(newly)
        if self.done and self.store._migration is self:
            self.store._migration = None  # detach: table v+1 is now total
        return matrices

    def _round(self) -> dict[tuple[int, int], int]:
        return self.live.round()

    def _pump_rounds(self) -> list[dict[tuple[int, int], int]]:
        return self.live.pump()


class CheckpointManager:
    """Save/restore jax pytrees against an AsuraCheckpointStore.

    Pass an ``obs.TraceLedger`` to get one span per save/restore
    (``checkpoint.save`` / ``checkpoint.restore`` with chunk and byte
    counts); without one the manager emits nothing.
    """

    def __init__(self, store: AsuraCheckpointStore, *, ledger=None):
        self.store = store
        self.ledger = ledger
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.saved_steps: list[int] = []

    # -- save ----------------------------------------------------------------

    def _chunks_of(self, step: int, tree: Any):
        leaves = jax.tree.leaves(tree)
        for li, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            raw = arr.tobytes()
            n = max(1, -(-len(raw) // CHUNK_BYTES))
            for ci in range(n):
                blob = raw[ci * CHUNK_BYTES : (ci + 1) * CHUNK_BYTES]
                yield chunk_id(step, li, ci), blob

    def save(self, step: int, tree: Any) -> None:
        from repro.obs.trace import maybe_span

        keys, blobs = [], []
        for key, blob in self._chunks_of(step, tree):
            keys.append(key)
            blobs.append(blob)
        with maybe_span(
            self.ledger,
            "checkpoint.save",
            step=step,
            n_chunks=len(keys),
            n_bytes=sum(len(b) for b in blobs),
        ):
            self.store.put_chunks(np.asarray(keys, dtype=np.uint32), blobs)
        self.saved_steps.append(step)

    def save_async(self, step: int, tree: Any) -> None:
        """Snapshot to host, then write on a thread (overlaps training)."""
        self.wait()
        snapshot = jax.tree.map(np.asarray, tree)

        def work():
            try:
                self.save(step, snapshot)
            except BaseException as e:  # surfaced by wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore --------------------------------------------------------------

    def restore(self, step: int, like: Any) -> Any:
        """Rebuild a pytree shaped like ``like`` from the store."""
        from repro.obs.trace import maybe_span

        leaves, treedef = jax.tree.flatten(like)
        out = []
        n_chunks = n_bytes = 0
        with maybe_span(self.ledger, "checkpoint.restore", step=step):
            for li, leaf in enumerate(leaves):
                arr = np.asarray(leaf)
                raw = arr.tobytes()
                n = max(1, -(-len(raw) // CHUNK_BYTES))
                parts = [
                    self.store.get_chunk(chunk_id(step, li, ci))
                    for ci in range(n)
                ]
                buf = b"".join(parts)
                n_chunks += n
                n_bytes += len(buf)
                out.append(
                    np.frombuffer(buf, dtype=arr.dtype).reshape(arr.shape)
                )
        if self.ledger is not None:
            self.ledger.incr("checkpoint.chunks_read", n_chunks)
            self.ledger.incr("checkpoint.bytes_read", n_bytes)
        return treedef.unflatten(out)
