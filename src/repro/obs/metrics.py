"""Device-plane metrics: one u32 slab the fused jits accumulate into.

``MetricsRegistry`` owns a single contiguous uint32 device array (the
"slab").  Counters and histograms are append-only layout entries -- a
name maps to a ``(offset, size)`` window of the slab, fixed at
registration time, so the in-jit helpers (``add`` / ``add_hist`` /
``bucket_add``) bake static offsets into the trace and cost one fused
scatter-add each.  The contract that makes this safe on the serving hot
path (DESIGN.md section 13):

  * the slab is threaded through the jitted step like any other device
    state (counts, queue, qhist): passed in, returned updated -- no
    side channels, no host sync per step;
  * a DISABLED registry (``enabled=False``) makes every helper a
    build-time no-op returning its operand unchanged, so enabled and
    disabled drivers compile the same number of traces;
  * metrics drain through ONE explicit ``snapshot()`` transfer, which
    zeroes the device slab and accumulates into host ``uint64`` totals
    (the device plane stays u32 -- TPUs have no u64 -- and overflow
    headroom lives on the host side of the drain);
  * under a mesh, a step accumulates into a zeros *delta* slab that
    merges with the existing per-node histogram in the step's single
    exact integer psum, so the sharded slab is bit-identical to the
    single-device slab (selftest-enforced).

Host-plane oddments that never touch the device (planner prefilter
counts, migration bytes) go through ``inc_host`` and drain through the
same ``snapshot()`` dict.
"""

from __future__ import annotations

import numpy as np


class MetricsRegistry:
    """Append-only u32 device slab of named counters and histograms."""

    def __init__(self, *, enabled: bool = True):
        self.enabled = bool(enabled)
        self._layout: dict[str, tuple[int, int]] = {}  # name -> (offset, size)
        self._size = 0
        self._slab = None  # lazily-built jax uint32 array, or None
        self._totals: dict[str, np.ndarray] = {}  # drained device totals (u64)
        self._host: dict[str, int] = {}  # host-plane counters

    # -- layout (host side, registration time) -------------------------------

    def _ensure(self, name: str, size: int) -> str:
        if not self.enabled:
            return name
        prev = self._layout.get(name)
        if prev is not None:
            if prev[1] != size:
                raise ValueError(
                    f"metric {name!r} already registered with size {prev[1]}, "
                    f"got {size}"
                )
            return name
        if size < 1:
            raise ValueError(f"metric {name!r} needs size >= 1, got {size}")
        self._layout[name] = (self._size, int(size))
        self._size += int(size)
        return name

    def counter(self, name: str) -> str:
        """Register (idempotently) a scalar counter; returns ``name``."""
        return self._ensure(name, 1)

    def histogram(self, name: str, n_bins: int) -> str:
        """Register (idempotently) an ``n_bins``-wide histogram."""
        return self._ensure(name, n_bins)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(self._layout)

    @property
    def size(self) -> int:
        return self._size

    # -- the device slab ------------------------------------------------------

    def slab(self):
        """The current device slab, grown (zero-padded) to the layout.

        Offsets are append-only, so growing preserves every live window;
        a growth retraces the consuming jit once (a shape change), which
        is the same benign retrace any new operand shape costs.
        """
        import jax.numpy as jnp

        if self._slab is None or int(self._slab.shape[0]) != self._size:
            old = self._slab
            self._slab = jnp.zeros((self._size,), jnp.uint32)
            if old is not None and int(old.shape[0]):
                self._slab = self._slab.at[: old.shape[0]].set(old)
        return self._slab

    def set_slab(self, slab) -> None:
        """Store the updated slab a jitted step returned (device array)."""
        self._slab = slab

    # -- traced accumulation helpers (static offsets, no captures) ------------

    def add(self, slab, name: str, value=1):
        """``slab[name] += value`` (scalar counter); traced-value safe."""
        if not self.enabled:
            return slab
        import jax.numpy as jnp

        off, _ = self._layout[name]
        return slab.at[off].add(jnp.asarray(value).astype(jnp.uint32))

    def add_hist(self, slab, name: str, values):
        """Add a whole per-bin vector into histogram ``name`` (the fused
        step already holds its batch histogram -- no rebinning needed)."""
        if not self.enabled:
            return slab
        import jax.numpy as jnp

        off, size = self._layout[name]
        v = jnp.asarray(values).astype(jnp.uint32)
        if int(v.shape[0]) > size:
            raise ValueError(
                f"histogram {name!r} holds {size} bins, got {int(v.shape[0])}"
            )
        return slab.at[off : off + int(v.shape[0])].add(v)

    def bucket_add(self, slab, name: str, idx, weight=1):
        """Scatter-add into histogram ``name`` at (clipped) bucket ``idx``."""
        if not self.enabled:
            return slab
        import jax.numpy as jnp

        off, size = self._layout[name]
        i = jnp.clip(jnp.asarray(idx).astype(jnp.int32), 0, size - 1)
        w = jnp.broadcast_to(jnp.asarray(weight).astype(jnp.uint32), i.shape)
        return slab.at[off + i].add(w)

    # -- host plane ------------------------------------------------------------

    def inc_host(self, name: str, n=1) -> int:
        """Host-side counter (control-path metrics: planner prefilter
        counts, migration bytes) -- drains through the same snapshot."""
        self._host[name] = c = self._host.get(name, 0) + int(n)
        return c

    # -- drain ----------------------------------------------------------------

    def _drain(self) -> None:
        if not (self.enabled and self._slab is not None and self._size):
            return
        import jax.numpy as jnp

        drained = np.zeros(self._size, np.uint64)
        live = np.asarray(self._slab).astype(np.uint64)  # the ONE transfer
        drained[: live.shape[0]] = live
        self._slab = jnp.zeros((self._size,), jnp.uint32)
        for name, (off, size) in self._layout.items():
            tot = self._totals.get(name)
            if tot is None:
                tot = self._totals[name] = np.zeros(size, np.uint64)
            tot += drained[off : off + size]

    def totals(self) -> dict:
        """Accumulated totals WITHOUT touching the device (what the last
        snapshot drained, plus the host-plane counters)."""
        out: dict = {}
        for name, (_, size) in self._layout.items():
            tot = self._totals.get(name)
            if tot is None:
                tot = np.zeros(size, np.uint64)
            out[name] = int(tot[0]) if size == 1 else tot.copy()
        for name, v in self._host.items():
            out[name] = int(v)
        return out

    def snapshot(self) -> dict:
        """Drain the device slab (ONE device->host transfer, slab resets
        to zero) and return the accumulated ``{name: int | uint64 array}``
        totals.  Totals are cumulative across snapshots."""
        self._drain()
        return self.totals()
