"""Two-plane telemetry for the placement/serving/migration stack.

Device plane (``obs.metrics``): ``MetricsRegistry`` owns one u32 device
slab that the fused jits accumulate into in-register -- routed counts,
per-node served histograms, ladder-depth histograms, re-probe and
non-convergence counts -- drained by ONE explicit ``snapshot()`` transfer
into host uint64 totals (DESIGN.md section 13).

Host plane (``obs.trace``): ``TraceLedger`` records timestamped
structured events (spans, uploads, jit traces, migration rounds) plus
monotonically-increasing host counters, with JSONL and Prometheus-style
text exporters.  The three ad-hoc trace tripwires (``engine.uploads``,
``RequestStreamDriver.step_traces``, the window/router probe counters)
are ledger counters behind back-compat aliases.
"""

from .metrics import MetricsRegistry
from .trace import TraceLedger, get_ledger, set_ledger

__all__ = ["MetricsRegistry", "TraceLedger", "get_ledger", "set_ledger"]
