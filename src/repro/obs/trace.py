"""Host-plane telemetry: timestamped structured events + counters.

``TraceLedger`` is the host half of the two-plane design (DESIGN.md
section 13): a bounded ring of structured events (span timings, artifact
uploads, LRU evictions, jit traces, migration rounds) plus a dict of
monotonically-increasing counters.  The three ad-hoc trace tripwires
that grew across PRs 2-7 (``engine.uploads``,
``RequestStreamDriver.step_traces``, the router/window probe counters)
are all ledger counters now, with the old attributes kept as read-only
aliases so every existing tripwire test reads the same way.

Counters are cheap (one dict update -- safe inside traced-body Python
side effects, which fire once per TRACE); events carry a timestamp from
an injectable clock (tests pass a fake) and export as JSONL (one object
per line) or Prometheus-style text exposition, optionally merged with a
``MetricsRegistry``'s drained device totals.

A module-level ledger (``get_ledger()``) serves call sites with no
instance to hang state on (the migration window's module-level probe
cache); everything else defaults to instance-scoped ledgers so exact
tripwire counts never alias across objects.
"""

from __future__ import annotations

import collections
import contextlib
import json
import re
import time

import numpy as np

DEFAULT_CAPACITY = 65536

_PROM_BAD = re.compile(r"[^a-zA-Z0-9_]")


def _jsonable(v):
    """Coerce an event field into something json.dumps accepts."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, np.ndarray):
        return v.tolist()
    return str(v)


class TraceLedger:
    """Bounded event ring + counter dict with JSONL/Prometheus export."""

    def __init__(self, *, clock=None, capacity: int = DEFAULT_CAPACITY):
        self._clock = clock if clock is not None else time.perf_counter
        self._events: collections.deque = collections.deque(maxlen=capacity)
        self._counters: dict[str, int] = {}

    # -- counters (the tripwire plane) ----------------------------------------

    def incr(self, name: str, n: int = 1) -> int:
        """Bump counter ``name`` by ``n``; returns the new value.  Cheap
        enough for traced-body side effects (fires once per jit TRACE)."""
        self._counters[name] = c = self._counters.get(name, 0) + int(n)
        return c

    def counter(self, name: str) -> int:
        return self._counters.get(name, 0)

    @property
    def counters(self) -> dict[str, int]:
        return dict(self._counters)

    # -- events ----------------------------------------------------------------

    def event(self, kind: str, name: str = "", **fields) -> dict:
        ev = {"ts": float(self._clock()), "kind": str(kind), "name": str(name)}
        for k, v in fields.items():
            ev[str(k)] = _jsonable(v)
        self._events.append(ev)
        return ev

    def events(self, kind: str | None = None) -> list[dict]:
        evs = list(self._events)
        if kind is None:
            return evs
        return [e for e in evs if e["kind"] == kind]

    @contextlib.contextmanager
    def span(self, name: str, **fields):
        """Time a block; emits one ``kind="span"`` event with ``dur_s``."""
        t0 = self._clock()
        try:
            yield
        finally:
            self.event("span", name, dur_s=float(self._clock() - t0), **fields)

    def clear(self) -> None:
        self._events.clear()

    # -- exporters --------------------------------------------------------------

    def to_jsonl(self) -> str:
        """One JSON object per line: every buffered event, then one
        ``kind="counters"`` summary line."""
        lines = [json.dumps(e, sort_keys=True) for e in self._events]
        if self._counters:
            lines.append(
                json.dumps(
                    {"kind": "counters", "counters": dict(self._counters)},
                    sort_keys=True,
                )
            )
        return "\n".join(lines) + ("\n" if lines else "")

    def export_jsonl(self, path: str) -> int:
        """Write ``to_jsonl()`` to ``path``; returns the event count."""
        with open(path, "w") as f:
            f.write(self.to_jsonl())
        return len(self._events)

    def prometheus_text(self, registry=None, *, prefix: str = "repro") -> str:
        """Prometheus-style text exposition of the counters (and, given a
        ``MetricsRegistry``, its drained device totals -- call
        ``registry.snapshot()`` first; this reads host totals only)."""

        def metric(name: str) -> str:
            return f"{prefix}_{_PROM_BAD.sub('_', name)}"

        lines: list[str] = []
        for name in sorted(self._counters):
            m = metric(name)
            lines.append(f"# TYPE {m} counter")
            lines.append(f"{m} {self._counters[name]}")
        if registry is not None:
            for name, v in sorted(registry.totals().items()):
                m = metric(name)
                if np.ndim(v) == 0:
                    lines.append(f"# TYPE {m} counter")
                    lines.append(f"{m} {int(v)}")
                else:
                    lines.append(f"# TYPE {m} histogram")
                    for i, c in enumerate(np.asarray(v).tolist()):
                        lines.append(f'{m}_bucket{{bin="{i}"}} {int(c)}')
        return "\n".join(lines) + ("\n" if lines else "")


# -- the module-level ledger (for module-level call sites) ---------------------

_GLOBAL: TraceLedger | None = None


def get_ledger() -> TraceLedger:
    """The process-wide default ledger (lazily created)."""
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = TraceLedger()
    return _GLOBAL


def set_ledger(ledger: TraceLedger) -> TraceLedger:
    """Swap the process-wide ledger (tests inject a fresh one); returns
    the previous ledger."""
    global _GLOBAL
    prev = get_ledger()
    _GLOBAL = ledger
    return prev


def maybe_span(ledger, name: str, **fields):
    """``ledger.span`` when a ledger is present, else a no-op context."""
    if ledger is None:
        return contextlib.nullcontext()
    return ledger.span(name, **fields)
